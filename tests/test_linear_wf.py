import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.linear_wf import banded_wf, banded_wf_numpy, full_wf_numpy


def _make_pair(r, n, eth, n_edits):
    """Random read + window holding an edited copy on the centre diagonal."""
    s1 = r.integers(0, 4, n).astype(np.uint8)
    lst = list(np.concatenate([r.integers(0, 4, eth), s1,
                               r.integers(0, 4, eth)]))
    for _ in range(n_edits):
        p = int(r.integers(eth, eth + n - 2))
        t = int(r.integers(0, 3))
        if t == 0:
            lst[p] = int(r.integers(0, 4))
        elif t == 1:
            lst.insert(p, int(r.integers(0, 4)))
        else:
            del lst[p]
    win = np.array((lst + [0] * (n + 2 * eth))[: n + 2 * eth], dtype=np.uint8)
    return s1, win


@given(st.integers(0, 10 ** 6), st.integers(10, 60), st.integers(0, 5))
@settings(max_examples=40, deadline=None)
def test_jnp_matches_numpy_oracle(seed, n, edits):
    r = np.random.default_rng(seed)
    eth = 6
    s1, win = _make_pair(r, n, eth, edits)
    _, d_np = banded_wf_numpy(s1, win, eth)
    d_end, d_min = banded_wf(jnp.array(s1), jnp.array(win), eth=eth)
    assert int(d_end) == d_np
    assert int(d_min) <= d_np


@given(st.integers(0, 10 ** 6), st.integers(10, 50))
@settings(max_examples=30, deadline=None)
def test_band_equals_full_when_within_eth(seed, n):
    """Ukkonen band correctness: if the true distance <= eth, the banded
    result is exact."""
    r = np.random.default_rng(seed)
    eth = 6
    s1, win = _make_pair(r, n, eth, int(r.integers(0, 4)))
    _, d_band = banded_wf_numpy(s1, win, eth)
    d_full = full_wf_numpy(s1, win[eth : eth + n])[n, n]
    if d_full <= eth:
        assert d_band == d_full
    else:
        assert d_band >= min(d_full, eth + 1) or d_band == eth + 1


@given(st.integers(0, 10 ** 6), st.integers(12, 40))
@settings(max_examples=20, deadline=None)
def test_identity_and_saturation(seed, n):
    r = np.random.default_rng(seed)
    eth = 6
    s1 = r.integers(0, 4, n).astype(np.uint8)
    win = np.concatenate([r.integers(0, 4, eth), s1,
                          r.integers(0, 4, eth)]).astype(np.uint8)
    d_end, _ = banded_wf(jnp.array(s1), jnp.array(win), eth=eth)
    assert int(d_end) == 0  # exact copy -> distance 0
    # a window of sentinel bases (never equal to any read base) saturates:
    # every path must pay >= n > eth edits
    s2w = np.full(len(win), 4, dtype=np.uint8)
    d_sat, _ = banded_wf(jnp.array(s1), jnp.array(s2w), eth=eth)
    assert int(d_sat) == eth + 1


def test_distance_bounded_by_edit_count():
    """Edit-distance upper bound: d <= number of substitutions applied."""
    r = np.random.default_rng(7)
    eth = 6
    for _ in range(20):
        n = int(r.integers(20, 80))
        s1 = r.integers(0, 4, n).astype(np.uint8)
        win = np.concatenate([r.integers(0, 4, eth), s1.copy(),
                              r.integers(0, 4, eth)]).astype(np.uint8)
        k = int(r.integers(0, 6))
        pos = r.choice(n, size=k, replace=False) if k else []
        for p in pos:
            win[eth + p] = (win[eth + p] + int(r.integers(1, 4))) % 4
        d_end, _ = banded_wf(jnp.array(s1), jnp.array(win), eth=eth)
        assert int(d_end) <= k


def test_batched_shapes():
    r = np.random.default_rng(3)
    eth = 6
    S1 = r.integers(0, 4, (4, 3, 25)).astype(np.uint8)
    S2 = r.integers(0, 4, (4, 3, 25 + 2 * eth)).astype(np.uint8)
    de, dm = banded_wf(jnp.array(S1), jnp.array(S2), eth=eth)
    assert de.shape == (4, 3) and dm.shape == (4, 3)
    for i in range(4):
        for j in range(3):
            _, dn = banded_wf_numpy(S1[i, j], S2[i, j], eth)
            assert int(de[i, j]) == dn
