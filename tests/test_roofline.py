"""Validate the analytic FLOPs model against XLA cost_analysis on configs
whose loops are fully unrolled (the documented methodology — see
repro/launch/roofline.py: cost_analysis counts while bodies once)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeCell
from repro.launch import roofline as rl
from repro.models import lm, transformer


def _hlo_flops_unrolled(cfg, B, S):
    """Compile an eval step with scan replaced by an unrolled loop."""
    from repro.models import transformer as tr

    params = tr.abstract_params(cfg)
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.input_kind == "embeds":
        batch = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                jnp.bfloat16),
                 "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    def fwd_unrolled(p, b):
        x = (tr.compute_dtype(p["embed"])[b["tokens"]]
             if cfg.input_kind == "tokens"
             else b["embeds"].astype(jnp.bfloat16))
        from repro.models.transformer import _block_fwd
        for i in range(cfg.n_layers):
            pl = jax.tree.map(lambda a: a[i], p["blocks"])
            x, _ = _block_fwd(x, pl, cfg, tr.layers.NO_SHARD)
        from repro.models import layers
        x = layers.apply_norm(x, p["final_norm"], cfg.norm)
        logits = x @ tr.compute_dtype(p["lm_head"])
        return jnp.sum(logits.astype(jnp.float32))

    c = jax.jit(fwd_unrolled).lower(params, batch).compile()
    return c.cost_analysis()["flops"]


# Compiled.cost_analysis() returns a per-computation *list* (not a dict)
# before jax 0.5 — a pre-existing seed failure on this container's jax
# 0.4.37, gated as an explicit skip.
from conftest import JAX_PRE_05  # noqa: E402

SKIP_PRE_05 = pytest.mark.skipif(
    JAX_PRE_05,
    reason="jax<0.5: Compiled.cost_analysis() returns a list, not a dict "
           "(pre-existing seed failure on jax 0.4.37)")


@SKIP_PRE_05
@pytest.mark.parametrize("arch", ["smollm-135m", "olmo-1b"])
def test_analytic_flops_vs_hlo_dense(arch):
    cfg = dataclasses.replace(reduced(ARCHS[arch]), remat=False, n_layers=3)
    B, S = 2, 256
    hlo = _hlo_flops_unrolled(cfg, B, S)
    model = rl.forward_flops(cfg, B * S, s_ctx=S)
    # HLO counts causal-masked full rectangles too (we pass s_ctx=S);
    # small ops (norms, rope, softmax) are not in the analytic model.
    assert model == pytest.approx(hlo, rel=0.15), (model, hlo)


@SKIP_PRE_05
def test_analytic_flops_vs_hlo_moe():
    cfg = dataclasses.replace(reduced(ARCHS["moonshot-v1-16b-a3b"]),
                              remat=False, n_layers=2)
    B, S = 2, 256
    hlo = _hlo_flops_unrolled(cfg, B, S)
    model = rl.forward_flops(cfg, B * S, s_ctx=S)
    assert model == pytest.approx(hlo, rel=0.25), (model, hlo)


def test_roofline_terms_reasonable():
    cfg = ARCHS["qwen2-vl-72b"]
    shape = ShapeCell("train_4k", 4096, 256, "train")
    r = rl.cell_roofline(cfg, shape, {"data": 16, "model": 16}, n_micro=16)
    # 72B x 1M tokens / 256 chips at 197 TF/s: seconds-scale step
    assert 1.0 < r.compute_s < 60.0
    assert r.dominant in ("compute", "memory", "collective")
    assert 0 < r.useful_ratio <= 1.0
    assert 0 < r.roofline_fraction <= 1.0


def test_decode_is_memory_bound():
    cfg = ARCHS["olmo-1b"]
    shape = ShapeCell("decode_32k", 32768, 128, "decode")
    r = rl.cell_roofline(cfg, shape, {"data": 16, "model": 16})
    assert r.dominant in ("memory", "collective")  # classic decode regime


def test_useful_ratio_definitions():
    cfg = ARCHS["qwen3-moe-235b-a22b"]
    shape = ShapeCell("train_4k", 4096, 256, "train")
    fl = rl.cell_flops(cfg, shape)
    # MoE useful flops use ACTIVE params
    assert fl["useful"] == 6 * cfg.active_params() * 256 * 4096
    assert fl["useful"] < fl["hlo_like_total"]
