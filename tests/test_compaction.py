"""Compacted execution engine: compaction primitives, Pallas-kernel parity
against the jnp references and the numpy band oracles, and compacted-vs-
padded pipeline equivalence."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.affine_wf import (banded_affine, banded_affine_dist,
                                  banded_affine_numpy)
from repro.core.compaction import (bucket_capacity, compact_indices,
                                   scatter_to)
from repro.core.linear_wf import banded_wf, banded_wf_numpy
from repro.kernels import ops

rng = np.random.default_rng(23)


# ---------------------------------------------------------------- primitives

def test_bucket_capacity_properties():
    for count in (0, 1, 2, 127, 128, 129, 1000, 5000):
        cap = bucket_capacity(count, align=128, cap_max=8192)
        assert cap & (cap - 1) == 0            # power of two
        assert cap % 128 == 0                  # lane-aligned
        assert cap >= min(max(count, 1), 8192)
    # ceiling: never exceeds next_pow2(cap_max)
    assert bucket_capacity(10 ** 9, align=128, cap_max=6144) == 8192
    # floor: never below align
    assert bucket_capacity(1, align=512, cap_max=8192) == 512


@given(st.integers(0, 10 ** 6), st.integers(1, 300))
@settings(max_examples=25, deadline=None)
def test_compact_scatter_roundtrip(seed, n):
    r = np.random.default_rng(seed)
    valid = jnp.asarray(r.random(n) < r.random())
    count = int(valid.sum())
    cap = bucket_capacity(count, align=8, cap_max=n)
    slots, slot_ok = compact_indices(valid, cap)
    # occupied slots list exactly the valid indices, original order kept
    want = np.flatnonzero(np.asarray(valid))[:cap]
    got = np.asarray(slots)[np.asarray(slot_ok)]
    np.testing.assert_array_equal(got, want)
    assert int(slot_ok.sum()) == min(count, cap)
    # scatter_to inverts the compaction
    vals = jnp.arange(cap, dtype=jnp.int32) + 100
    back = np.asarray(scatter_to(n, slots, slot_ok, vals, jnp.int32(-1)))
    assert (back[~np.asarray(valid)] == -1).all()
    for s, f in zip(got, range(len(got))):
        assert back[s] == 100 + f


# ------------------------------------------------- kernels vs numpy oracles

def _rand_pairs(R, n, eth, seed=0):
    r = np.random.default_rng(seed)
    s1 = r.integers(0, 4, (R, n)).astype(np.uint8)
    s2 = r.integers(0, 4, (R, n + 2 * eth)).astype(np.uint8)
    # half the instances hold a lightly-edited copy on the centre diagonal
    s2[: R // 2, eth : eth + n] = s1[: R // 2]
    for i in range(R // 2):
        for _ in range(int(r.integers(0, 4))):
            s2[i, eth + int(r.integers(0, n))] = r.integers(0, 4)
    return s1, s2


@pytest.mark.parametrize("R,n,eth", [(16, 24, 6), (24, 40, 4)])
def test_linear_pallas_matches_numpy_oracle(R, n, eth):
    s1, s2 = _rand_pairs(R, n, eth, seed=3)
    de, dm = ops.linear_wf(jnp.asarray(s1), jnp.asarray(s2), eth=eth,
                           block_r=8)
    je, jm = banded_wf(jnp.asarray(s1), jnp.asarray(s2), eth=eth)
    np.testing.assert_array_equal(np.asarray(de), np.asarray(je))
    np.testing.assert_array_equal(np.asarray(dm), np.asarray(jm))
    for i in range(R):
        B, d_np = banded_wf_numpy(s1[i], s2[i], eth)
        assert int(de[i]) == d_np
        assert int(dm[i]) == int(B[n].min())


@pytest.mark.parametrize("R,n,eth,sat", [(12, 24, 6, 32), (16, 30, 4, 16)])
def test_affine_pallas_matches_numpy_oracle(R, n, eth, sat):
    s1, s2 = _rand_pairs(R, n, eth, seed=5)
    de, dm, dirs = ops.affine_wf(jnp.asarray(s1), jnp.asarray(s2), eth=eth,
                                 sat=sat, block_r=4)
    je, jm, jdirs = banded_affine(jnp.asarray(s1), jnp.asarray(s2), eth=eth,
                                  sat=sat)
    np.testing.assert_array_equal(np.asarray(de), np.asarray(je))
    np.testing.assert_array_equal(np.asarray(dirs), np.asarray(jdirs))
    for i in range(R):
        D, dirs_np, dist = banded_affine_numpy(s1[i], s2[i], eth=eth, sat=sat)
        assert int(de[i]) == dist
        assert int(dm[i]) == int(D.min())
        np.testing.assert_array_equal(np.asarray(dirs[i]), dirs_np)


@pytest.mark.parametrize("R,n,eth,sat", [(16, 24, 6, 32), (12, 36, 4, 16)])
def test_affine_dist_variants_match_dirs_variant(R, n, eth, sat):
    """banded_affine_dist (jnp) and affine_wf_dist (Pallas) return exactly
    the distances of the dirs-emitting reference."""
    s1, s2 = _rand_pairs(R, n, eth, seed=7)
    je, jm, _ = banded_affine(jnp.asarray(s1), jnp.asarray(s2), eth=eth,
                              sat=sat)
    de, dm = banded_affine_dist(jnp.asarray(s1), jnp.asarray(s2), eth=eth,
                                sat=sat)
    np.testing.assert_array_equal(np.asarray(de), np.asarray(je))
    np.testing.assert_array_equal(np.asarray(dm), np.asarray(jm))
    ke, km = ops.affine_wf_dist(jnp.asarray(s1), jnp.asarray(s2), eth=eth,
                                sat=sat, block_r=8)
    np.testing.assert_array_equal(np.asarray(ke), np.asarray(je))
    np.testing.assert_array_equal(np.asarray(km), np.asarray(jm))


# --------------------------------------------- pipeline engine equivalence

@pytest.fixture(scope="module")
def small_world():
    from repro.core.index import build_index
    from repro.data.genome import make_reference, sample_reads
    ref = make_reference(8_000, seed=11, repeat_frac=0.03)
    idx = build_index(ref)
    rs = sample_reads(ref, 40, seed=13)
    junk = np.random.default_rng(15).integers(0, 4, (8, 150)).astype(np.uint8)
    reads = np.concatenate([rs.reads, junk])  # include unmapped reads
    return idx, reads


def _assert_same_mapping(a, b):
    for f in ("position", "distance", "mapped", "ops", "op_count",
              "linear_dist", "n_candidates"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f)


def test_compacted_equals_padded_jnp(small_world):
    from repro.core.pipeline import MapperConfig, map_reads
    idx, reads = small_world
    a = map_reads(idx, reads, MapperConfig(engine="padded"))
    b = map_reads(idx, reads, MapperConfig(engine="compacted"))
    _assert_same_mapping(a, b)
    assert b.stats is not None
    assert b.stats["survivors"] <= b.stats["candidates_valid"]
    assert b.stats["linear_instances"] < b.stats["padded_linear_instances"]


def test_compacted_equals_padded_chunked(small_world):
    from repro.core.pipeline import MapperConfig, map_reads
    idx, reads = small_world
    a = map_reads(idx, reads, MapperConfig(engine="padded"))
    b = map_reads(idx, reads, MapperConfig(engine="compacted",
                                           chunk_reads=14))
    _assert_same_mapping(a, b)
    assert b.stats["n_chunks"] == 4


def test_pallas_backend_equals_jnp_reference(small_world):
    """map_reads with wf_backend="pallas" (interpret mode on CPU) produces
    identical positions/distances to the jnp reference."""
    from repro.core.pipeline import MapperConfig, map_reads
    idx, reads = small_world
    a = map_reads(idx, reads, MapperConfig(engine="padded",
                                           wf_backend="jnp"))
    b = map_reads(idx, reads, MapperConfig(engine="compacted",
                                           wf_backend="pallas",
                                           lin_block_r=128, aff_block_r=64))
    _assert_same_mapping(a, b)


def test_unknown_engine_and_backend_raise(small_world):
    from repro.core.pipeline import MapperConfig, map_reads
    from repro.core import wf_backend as wfb
    idx, reads = small_world
    with pytest.raises(ValueError):
        map_reads(idx, reads[:4], MapperConfig(engine="nope"))
    with pytest.raises(ValueError):
        wfb.linear_wf_dist(jnp.zeros((2, 10), jnp.uint8),
                           jnp.zeros((2, 22), jnp.uint8), eth=6,
                           backend="cuda")
