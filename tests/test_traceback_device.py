"""On-device banded traceback: the batched ``affine_wf.traceback`` walk
and the fused affine+traceback Pallas kernel must match the
``traceback_numpy`` oracle — including band-edge walks, all-match reads,
adjacent insertion/deletion runs, and the ``max_ops`` truncation wrap."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import wf_backend as wfb
from repro.core.affine_wf import (OP_DEL, OP_INS, OP_MATCH, OP_NONE,
                                  banded_affine, banded_affine_numpy,
                                  traceback, traceback_numpy)

ETH, SAT = 6, 32


def _make_pair(r, n, n_edits):
    """Read + window with ``n_edits`` random substitutions/indels (the
    same generator as test_affine_wf): enough edits pushes the walk to
    the band edges and produces adjacent gap runs."""
    s1 = r.integers(0, 4, n).astype(np.uint8)
    lst = list(np.concatenate([r.integers(0, 4, ETH), s1,
                               r.integers(0, 4, ETH)]))
    for _ in range(n_edits):
        p = int(r.integers(ETH, ETH + n - 2))
        t = int(r.integers(0, 3))
        if t == 0:
            lst[p] = int(r.integers(0, 4))
        elif t == 1:
            lst.insert(p, int(r.integers(0, 4)))
        else:
            del lst[p]
    win = np.array((lst + [0] * (n + 2 * ETH))[: n + 2 * ETH],
                   dtype=np.uint8)
    return s1, win


def _oracle_rows(dirs_list, n, max_ops):
    """END-aligned op rows + counts the way the oracle defines them: op k
    (counting from the end of the walk) lands at ``(max_ops - 1 - k) %
    max_ops``, later walk steps overwriting on wrap — the truncation
    semantics the device walk must reproduce bit-for-bit."""
    rows = np.full((len(dirs_list), max_ops), OP_NONE, np.int32)
    cnts = np.zeros(len(dirs_list), np.int32)
    for i, dirs in enumerate(dirs_list):
        ops = traceback_numpy(dirs, ETH, n)
        cnts[i] = len(ops)
        for k, op in enumerate(reversed(ops)):
            rows[i, (max_ops - 1 - k) % max_ops] = op
    return rows, cnts


@given(st.integers(0, 10 ** 6), st.integers(10, 50), st.integers(0, 6))
@settings(max_examples=30, deadline=None)
def test_device_walk_matches_oracle(seed, n, edits):
    r = np.random.default_rng(seed)
    s1, win = _make_pair(r, n, edits)
    _, dirs_np, _ = banded_affine_numpy(s1, win, ETH, SAT)
    max_ops = 2 * n + 2
    exp_rows, exp_cnt = _oracle_rows([dirs_np], n, max_ops)
    ops, cnt = traceback(jnp.array(dirs_np)[None], ETH, max_ops)
    np.testing.assert_array_equal(np.asarray(ops), exp_rows)
    np.testing.assert_array_equal(np.asarray(cnt), exp_cnt)


@given(st.integers(0, 10 ** 6), st.integers(12, 40),
       st.integers(1, 2 * 40))
@settings(max_examples=25, deadline=None)
def test_max_ops_truncation_wrap(seed, n, max_ops):
    """A ``max_ops`` buffer smaller than the walk must hold exactly the
    oracle's wrapped tail (the SAM layer then reports those as '*')."""
    r = np.random.default_rng(seed)
    s1, win = _make_pair(r, n, int(r.integers(0, 5)))
    _, dirs_np, _ = banded_affine_numpy(s1, win, ETH, SAT)
    exp_rows, exp_cnt = _oracle_rows([dirs_np], n, max_ops)
    ops, cnt = traceback(jnp.array(dirs_np)[None], ETH, max_ops)
    np.testing.assert_array_equal(np.asarray(ops), exp_rows)
    np.testing.assert_array_equal(np.asarray(cnt), exp_cnt)


def _batch(r, n, count, edit_pool):
    s1s, wins = zip(*(_make_pair(r, n, edit_pool[i % len(edit_pool)])
                      for i in range(count)))
    return np.stack(s1s), np.stack(wins)


def test_fused_kernel_matches_jnp_backend():
    """`wf_backend.affine_traceback`: the Pallas fused kernel (dirs in
    VMEM scratch) against the jnp reference, distances included."""
    r = np.random.default_rng(7)
    n = 24
    s1, win = _batch(r, n, 12, edit_pool=(0, 1, 2, 3, 4, 5))
    outs = {}
    for backend in ("jnp", "pallas"):
        outs[backend] = wfb.affine_traceback(
            jnp.asarray(s1), jnp.asarray(win), eth=ETH, sat=SAT,
            max_ops=2 * n + 2, backend=backend, block_r=8)
    for a, b, name in zip(outs["jnp"], outs["pallas"],
                          ("dist_end", "dist_min", "ops", "op_count")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)
    # and the jnp side against the oracle, so both chains are anchored
    dirs = [banded_affine_numpy(s1[i], win[i], ETH, SAT)[1]
            for i in range(len(s1))]
    exp_rows, exp_cnt = _oracle_rows(dirs, n, 2 * n + 2)
    np.testing.assert_array_equal(np.asarray(outs["jnp"][2]), exp_rows)
    np.testing.assert_array_equal(np.asarray(outs["jnp"][3]), exp_cnt)


def test_all_match_and_gap_runs_both_backends():
    """Degenerate shapes: an exact-match read (walk = straight diagonal)
    and the affine gap-run pair (adjacent 2-insertion + 2-deletion runs)
    in one batch, on both backends."""
    n = 12
    origin = np.array([0, 1, 2, 3] * 5, dtype=np.uint8)
    exact = origin[:n]
    win_exact = np.concatenate([np.full(ETH, 4, np.uint8), exact,
                                np.full(ETH, 4, np.uint8)])
    gap_read = np.concatenate([origin[:4], [3, 3],
                               origin[4:10]]).astype(np.uint8)
    win_gap = np.concatenate([np.full(ETH, 4, np.uint8), origin[:n],
                              np.full(ETH, 4, np.uint8)])
    s1 = np.stack([exact, gap_read])
    win = np.stack([win_exact, win_gap])
    max_ops = 2 * n + 2
    for backend in ("jnp", "pallas"):
        de, _, ops, cnt = wfb.affine_traceback(
            jnp.asarray(s1), jnp.asarray(win), eth=ETH, sat=SAT,
            max_ops=max_ops, backend=backend, block_r=8)
        ops, cnt = np.asarray(ops), np.asarray(cnt)
        assert int(de[0]) == 0 and int(cnt[0]) == n
        assert (ops[0, -n:] == OP_MATCH).all()
        assert (ops[0, :-n] == OP_NONE).all()
        walk = [int(o) for o in ops[1] if o != OP_NONE]
        assert int(de[1]) == 6 and len(walk) == int(cnt[1])
        runs = {OP_INS: [], OP_DEL: []}
        prev = None
        for o in walk:
            if o in runs:
                if o == prev:
                    runs[o][-1] += 1
                else:
                    runs[o].append(1)
            prev = o
        assert 2 in runs[OP_INS] and 2 in runs[OP_DEL], backend


def test_traceback_matches_banded_affine_plus_walk():
    """The one-dispatch ``wfb.affine_traceback`` must equal running the
    staged pair (dirs-emitting affine, then the batched walk)."""
    r = np.random.default_rng(11)
    n = 30
    s1, win = _batch(r, n, 6, edit_pool=(0, 2, 4))
    de_s, dm_s, dirs = banded_affine(jnp.asarray(s1), jnp.asarray(win),
                                     eth=ETH, sat=SAT)
    ops_s, cnt_s = traceback(dirs, ETH, 2 * n + 2)
    de_f, dm_f, ops_f, cnt_f = wfb.affine_traceback(
        jnp.asarray(s1), jnp.asarray(win), eth=ETH, sat=SAT,
        max_ops=2 * n + 2, backend="jnp")
    np.testing.assert_array_equal(np.asarray(de_s), np.asarray(de_f))
    np.testing.assert_array_equal(np.asarray(dm_s), np.asarray(dm_f))
    np.testing.assert_array_equal(np.asarray(ops_s), np.asarray(ops_f))
    np.testing.assert_array_equal(np.asarray(cnt_s), np.asarray(cnt_f))
