import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.encoding import (decode_to_str, encode_str, kmer_codes,
                                 pack_2bit, unpack_2bit)
from repro.core.minimizers import (hash32, minimizers, sliding_argmin,
                                   sliding_min, unique_read_minimizers)

rng = np.random.default_rng(0)


def test_encoding_roundtrip():
    s = "ACGTACGTTTGACA"
    c = encode_str(s)
    assert decode_to_str(c) == s
    assert (unpack_2bit(pack_2bit(c), len(c)) == c).all()


@given(st.lists(st.integers(0, 3), min_size=12, max_size=64))
@settings(max_examples=30, deadline=None)
def test_kmer_codes_match_reference(seq):
    seq = np.array(seq, dtype=np.uint8)
    k = 12
    codes = np.array(kmer_codes(jnp.array(seq), k))
    for i in range(len(seq) - k + 1):
        ref = 0
        for j in range(k):
            ref = (ref << 2) | int(seq[i + j])
        assert codes[i] == ref


@given(st.integers(1, 20), st.integers(0, 10 ** 6))
@settings(max_examples=25, deadline=None)
def test_sliding_min_and_argmin(window, seed):
    r = np.random.default_rng(seed)
    v = r.integers(0, 50, window + int(r.integers(0, 40))).astype(np.uint32)
    got = np.array(sliding_min(jnp.array(v), window))
    mn, am = sliding_argmin(jnp.array(v), window)
    for i in range(len(v) - window + 1):
        w = v[i : i + window]
        assert got[i] == w.min()
        assert np.array(mn)[i] == w.min()
        assert np.array(am)[i] == i + int(np.argmin(w))  # leftmost tie


def test_minimizer_positions_bruteforce():
    seq = rng.integers(0, 4, 300).astype(np.uint8)
    mh, mk, mp = minimizers(jnp.array(seq), k=12, w=30)
    codes = np.array(kmer_codes(jnp.array(seq), 12))
    hs = np.array(hash32(jnp.array(codes)))
    for i in range(len(np.array(mh))):
        w = hs[i : i + 30]
        assert np.array(mh)[i] == w.min()
        assert np.array(mp)[i] == i + int(np.argmin(w))


def test_unique_read_minimizers_dedup():
    read = rng.integers(0, 4, 150).astype(np.uint8)
    ks, ps, valid = unique_read_minimizers(jnp.array(read))
    kk = np.array(ks)[np.array(valid)]
    assert len(set(kk.tolist())) == len(kk)
    # all returned positions are actual minimizer positions
    _, mk, mp = minimizers(jnp.array(read), k=12, w=30)
    real = set(zip(np.array(mk).tolist(), np.array(mp).tolist()))
    for kmer, pos in zip(kk, np.array(ps)[np.array(valid)]):
        assert (int(kmer), int(pos)) in real


def test_hash32_invertible_no_collisions_sample():
    xs = rng.integers(0, 2 ** 24, 4096, dtype=np.uint32)
    hs = np.array(hash32(jnp.array(xs, dtype=jnp.uint32)))
    assert len(np.unique(hs)) == len(np.unique(xs))
