"""Sharded out-of-core index: builder/format correctness.

The load-bearing property: the streamed, tiled, partitioned builder is
**bit-identical** to the flat in-memory ``core.index.build_index`` over
the same (spacer-concatenated) reference — same kmers, same CSR, same
positions, same segments — for any tile size, any partition count, and
after any save/load round trip.  Plus: the numpy scan kernels match
their jax originals, sharded lookups union back to the flat lookup
(property-based), peak build memory is bounded by the tile (not the
genome), and corruption is caught by the manifest digests.
"""
import json
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.index import SENTINEL, build_index, validate_geometry
from repro.core.minimizers import minimizers, unique_read_minimizers
from repro.data.genome import make_reference, write_fasta
from repro.index import (IndexIntegrityError, build_sharded_index,
                         load_index, open_index, shard_flat_index,
                         verify_index)
from repro.index.format import pack_codes, unpack_codes
from repro.index.npscan import (np_hash32, np_minimizers,
                                np_unique_read_minimizers)

READ_LEN, K, W, ETH = 60, 10, 12, 4


# ---------------------------------------------------------------- np parity

def test_np_minimizers_match_jax():
    rng = np.random.default_rng(0)
    for n in (W + K - 1, 100, 997):
        seq = rng.integers(0, 4, n).astype(np.uint8)
        jm, jk, jp = (np.asarray(a) for a in minimizers(seq, k=K, w=W))
        nm, nk, npos = np_minimizers(seq, K, W)
        assert np.array_equal(jm, nm)
        assert np.array_equal(jk, nk)
        assert np.array_equal(jp, npos)


def test_np_unique_read_minimizers_match_jax():
    rng = np.random.default_rng(1)
    reads = rng.integers(0, 4, (16, READ_LEN)).astype(np.uint8)
    for max_uniq in (4, 16):
        nk, npos, nv = np_unique_read_minimizers(reads, K, W, max_uniq)
        for r in range(len(reads)):
            jk, jp, jv = (np.asarray(a) for a in unique_read_minimizers(
                reads[r], k=K, w=W, max_uniq=max_uniq))
            assert np.array_equal(jk, nk[r]), r
            assert np.array_equal(jp, npos[r]), r
            assert np.array_equal(jv, nv[r]), r


# ------------------------------------------------- lookup union property

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 3))
def test_sharded_lookup_union_equals_flat(seed, log2p):
    num_partitions = 1 << log2p   # 1, 2, 4, 8 (stub-compatible strategy)
    rng = np.random.default_rng(seed)
    ref = rng.integers(0, 4, int(rng.integers(200, 2000))).astype(np.uint8)
    flat = build_index(ref, read_len=READ_LEN, k=K, w=W, eth=ETH)
    sidx = shard_flat_index(flat, num_partitions)
    uniq = np.asarray(flat.uniq_kmers)
    # every indexed kmer, plus kmers absent from the index
    probe = np.concatenate([uniq, rng.integers(0, 4**K, 8).astype(np.uint32)])
    for km in probe:
        i = int(np.searchsorted(uniq, km))
        if i < len(uniq) and uniq[i] == km:
            expect = flat.positions[flat.offsets[i]:flat.offsets[i + 1]]
        else:
            expect = np.zeros(0, np.int32)
        got = sidx.lookup(int(km))
        assert np.array_equal(np.sort(got), np.sort(expect)), hex(int(km))
    # kmers land wholly in their routed partition and nowhere else
    owner = np.asarray(sidx.route(uniq))
    for p, part in enumerate(sidx.parts):
        assert np.array_equal(np.asarray(part.kmers),
                              np.sort(uniq[owner == p]))


# --------------------------------------------------- builder equivalence

@pytest.fixture(scope="module")
def genome_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("sharded_idx")
    rng = np.random.default_rng(7)
    contigs = [("chr1", make_reference(4000, seed=1, repeat_frac=0.05)),
               ("chr2", make_reference(2500, seed=2, repeat_frac=0.0)),
               ("chr3", rng.integers(0, 4, 900).astype(np.uint8))]
    contigs[0][1][150:156] = 4  # an N run inside a contig
    write_fasta(d / "ref.fa", contigs)
    spacer = READ_LEN + 2 * ETH
    cat = []
    for i, (_, codes) in enumerate(contigs):
        if i:
            cat.append(np.full(spacer, SENTINEL, np.uint8))
        cat.append(codes)
    ref = np.concatenate(cat)
    flat = build_index(ref, read_len=READ_LEN, k=K, w=W, eth=ETH)
    return d, contigs, ref, flat


def _assert_flat_equal(g, flat):
    assert np.array_equal(g.uniq_kmers, flat.uniq_kmers)
    assert np.array_equal(g.offsets, flat.offsets)
    assert np.array_equal(g.positions, flat.positions)
    assert np.array_equal(g.segments, flat.segments)


def test_build_bit_identical_to_flat_and_tile_invariant(genome_dir):
    d, contigs, ref, flat = genome_dir
    idx = build_sharded_index(d / "ref.fa", d / "idx", num_partitions=4,
                              tile_bp=512, read_len=READ_LEN, k=K, w=W,
                              eth=ETH)
    _assert_flat_equal(idx.to_genome_index(), flat)
    # a different tile size produces byte-identical partitions
    idx_big = build_sharded_index(d / "ref.fa", d / "idx_big",
                                  num_partitions=4, tile_bp=1 << 20,
                                  read_len=READ_LEN, k=K, w=W, eth=ETH)
    for pa, pb in zip(idx.parts, idx_big.parts):
        assert np.array_equal(np.asarray(pa.kmers), np.asarray(pb.kmers))
        assert np.array_equal(np.asarray(pa.positions),
                              np.asarray(pb.positions))
        assert np.array_equal(pa.read_segments(), pb.read_segments())
    # in-memory partitioner agrees with the on-disk builder
    sidx = shard_flat_index(flat, 4)
    for pa, pb in zip(idx.parts, sidx.parts):
        assert np.array_equal(np.asarray(pa.kmers), np.asarray(pb.kmers))
        assert np.array_equal(np.asarray(pa.offsets),
                              np.asarray(pb.offsets))
        assert np.array_equal(np.asarray(pa.positions),
                              np.asarray(pb.positions))
    # contig table + packed reference round-trip
    assert [(c.name, c.length) for c in idx.contigs] == \
        [(n, len(codes)) for n, codes in contigs]
    assert np.array_equal(idx.reference_codes(), ref)


def test_reload_identical_and_integrity(genome_dir, tmp_path):
    d, _, _, flat = genome_dir
    out = tmp_path / "idx"
    build_sharded_index(d / "ref.fa", out, num_partitions=2, tile_bp=777,
                        read_len=READ_LEN, k=K, w=W, eth=ETH)
    verify_index(out)  # full digest pass on the fresh build
    for opener in (open_index, load_index):
        _assert_flat_equal(opener(out).to_genome_index(), flat)
    # refuses to clobber without overwrite=True
    with pytest.raises(ValueError, match="already holds an index"):
        build_sharded_index(d / "ref.fa", out, num_partitions=2,
                            read_len=READ_LEN, k=K, w=W, eth=ETH)

    # corrupt one byte of a partition payload -> digest check catches it
    target = out / "part0000.positions.npy"
    blob = bytearray(target.read_bytes())
    blob[-1] ^= 0xFF
    target.write_bytes(bytes(blob))
    with pytest.raises(IndexIntegrityError, match="crc32"):
        verify_index(out)
    # size-only checks (the open_index default) still pass on a bit flip,
    # but catch truncation
    open_index(out, verify="size")
    target.write_bytes(bytes(blob[:-8]))
    with pytest.raises(IndexIntegrityError):
        open_index(out, verify="size")


def test_manifest_version_gate(genome_dir, tmp_path):
    d, _, _, _ = genome_dir
    out = tmp_path / "idx"
    build_sharded_index(d / "ref.fa", out, num_partitions=1,
                        read_len=READ_LEN, k=K, w=W, eth=ETH)
    man = json.loads((out / "manifest.json").read_text())
    man["format"] = "repro-sharded-index/999"
    (out / "manifest.json").write_text(json.dumps(man))
    with pytest.raises(ValueError, match="repro-sharded-index/999"):
        open_index(out)


def test_pl_cap_matches_flat(tmp_path):
    # a tandem repeat drives one minimizer far past the cap
    rng = np.random.default_rng(3)
    unit = rng.integers(0, 4, 40).astype(np.uint8)
    ref = np.concatenate([np.tile(unit, 60),
                          rng.integers(0, 4, 1500).astype(np.uint8)])
    cap = 8
    flat = build_index(ref, read_len=READ_LEN, k=K, w=W, eth=ETH,
                       max_pls_per_minimizer=cap)
    write_fasta(tmp_path / "rep.fa", [("chrR", ref)])
    idx = build_sharded_index(tmp_path / "rep.fa", tmp_path / "idx",
                              num_partitions=4, tile_bp=333,
                              read_len=READ_LEN, k=K, w=W, eth=ETH,
                              max_pls_per_minimizer=cap)
    _assert_flat_equal(idx.to_genome_index(), flat)
    assert idx.manifest["build"]["dropped_pls"] > 0


# ---------------------------------------------------------- bounded memory

def test_build_peak_memory_bounded_by_tile(tmp_path):
    """Peak builder RSS stays far below the flat build's segment
    materialization when tile_bp << genome.  The builder is pure numpy,
    so tracemalloc sees every allocation that matters."""
    import tracemalloc

    ref = make_reference(400_000, seed=11, repeat_frac=0.01)
    write_fasta(tmp_path / "big.fa", [("chrB", ref)])
    tile = 4096
    tracemalloc.start()
    tracemalloc.reset_peak()
    idx = build_sharded_index(tmp_path / "big.fa", tmp_path / "idx",
                              num_partitions=4, tile_bp=tile,
                              read_len=READ_LEN, k=K, w=W, eth=ETH)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    seg_len = idx.seg_len
    flat_seg_bytes = idx.n_occurrences * seg_len  # uint8 flat segments
    assert idx.n_occurrences > 10_000  # the genome is genuinely large
    assert peak < flat_seg_bytes / 3, (peak, flat_seg_bytes)


# -------------------------------------------------------------- validation

@pytest.mark.parametrize("kw,msg", [
    (dict(read_len=0, k=12, w=30, eth=6), r"read_len=0.*must be >= 1"),
    (dict(read_len=150, k=0, w=30, eth=6), r"k=0.*within \[1, 16\]"),
    (dict(read_len=150, k=17, w=30, eth=6), r"k=17.*within \[1, 16\]"),
    (dict(read_len=10, k=12, w=30, eth=6),
     r"k=12 exceeds read_len=10.*no k-mers"),
    (dict(read_len=150, k=12, w=0, eth=6), r"w=0.*must be >= 1"),
    (dict(read_len=150, k=12, w=30, eth=-1), r"eth=-1.*must be >= 0"),
])
def test_validate_geometry_messages(kw, msg):
    with pytest.raises(ValueError, match=msg):
        validate_geometry(**kw)


def test_mapper_config_and_build_index_validate():
    from repro.core.pipeline import MapperConfig
    with pytest.raises(ValueError, match=r"w=0"):
        MapperConfig(read_len=100, w=0)
    with pytest.raises(ValueError, match=r"k=12 exceeds read_len=8"):
        MapperConfig(read_len=8)
    with pytest.raises(ValueError, match=r"k=12 exceeds read_len=4"):
        build_index(np.zeros(100, np.uint8), read_len=4)


def test_build_sharded_index_validation(tmp_path, genome_dir):
    d, _, _, _ = genome_dir
    for bad in (0, 3, 6, -4):
        with pytest.raises(ValueError,
                           match=rf"num_partitions={bad}.*power of two"):
            build_sharded_index(d / "ref.fa", tmp_path / "x",
                                num_partitions=bad, read_len=READ_LEN,
                                k=K, w=W, eth=ETH)
    with pytest.raises(ValueError, match=r"tile_bp=4.*minimizer window"):
        build_sharded_index(d / "ref.fa", tmp_path / "x", tile_bp=4,
                            read_len=READ_LEN, k=K, w=W, eth=ETH)
    with pytest.raises(ValueError, match="no sequence"):
        empty = tmp_path / "empty.fa"
        empty.write_text(">c1\n")
        build_sharded_index(empty, tmp_path / "y", read_len=READ_LEN,
                            k=K, w=W, eth=ETH)


# ------------------------------------------------------- storage accounting

def test_storage_bytes_true_packed(genome_dir):
    _, _, _, flat = genome_dir
    st_flat = flat.storage_bytes()
    n_occ, seg_len = len(flat.positions), flat.seg_len
    assert st_flat["materialized_segments_bytes"] == \
        n_occ * ((seg_len + 3) // 4 + (seg_len + 7) // 8)
    assert st_flat["total_bytes"] == (st_flat["hash_table_bytes"]
                                      + st_flat["materialized_segments_bytes"])
    sidx = shard_flat_index(flat, 4)
    st_sh = sidx.storage_bytes()
    assert len(st_sh["per_partition"]) == 4
    assert sum(d["segments_bytes"] for d in st_sh["per_partition"]) == \
        st_sh["materialized_segments_bytes"]
    assert st_sh["materialized_segments_bytes"] == \
        st_flat["materialized_segments_bytes"]


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(4)
    for n in (1, 4, 7, 8, 31, 64):
        codes = rng.integers(0, 5, n).astype(np.uint8)  # incl. sentinel
        packed, sent = pack_codes(codes)
        assert np.array_equal(unpack_codes(packed, sent, n), codes)


def test_hash32_matches_distributed_rule():
    from repro.core.minimizers import hash32
    import jax.numpy as jnp
    x = np.random.default_rng(5).integers(0, 2**32, 257, dtype=np.uint32)
    assert np.array_equal(np_hash32(x), np.asarray(hash32(jnp.asarray(x))))
