"""Chaos suite (``-m chaos``): deterministic fault injection against the
full serving stack.  Every test arms a seeded ``FaultInjector`` and
asserts the containment contract — a fault takes down only the work that
caused it, every request id resolves exactly once, and the healthy
fraction of the stream is bit-identical to a fault-free run."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.mapper import Mapper
from repro.core.pipeline import MapperConfig
from repro.core.resilience import (FaultInjector, FetchStallError,
                                   InjectedFault, MappingError,
                                   ResilientMapper, RetryPolicy)
from repro.core.serving import BatcherConfig, MappingService

pytestmark = pytest.mark.chaos

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
FAST = RetryPolicy(max_attempts=3, backoff_s=0.0, bisect_min=4,
                   degrade_after=2)


@pytest.fixture(scope="module")
def world():
    from repro.core.index import build_index
    from repro.data.genome import make_reference, sample_reads
    ref = make_reference(8_000, seed=11, repeat_frac=0.03)
    idx = build_index(ref)
    rs = sample_reads(ref, 96, seed=13)
    return ref, idx, rs.reads


@pytest.fixture(scope="module")
def mesh1(world):
    from repro.core.distributed import shard_index
    from repro.core.mapper import _flat_mesh
    _, idx, _ = world
    return _flat_mesh(1), shard_index(idx, 1)


# ----------------------------------------------------- streaming engine

def test_fetch_stall_trips_watchdog(world):
    _, idx, reads = world
    inj = FaultInjector(rates={"fetch_stall": 1.0}, stall_s=2.0)
    mapper = Mapper(idx, MapperConfig(engine="compacted", chunk_reads=32),
                    injector=inj, watchdog_s=0.25)
    with pytest.raises(FetchStallError, match="watchdog"):
        mapper.map(reads)
    assert inj.fired["fetch_stall"] >= 1


def test_fetch_error_propagates_promptly(world):
    _, idx, reads = world
    inj = FaultInjector(rates={"fetch_error": 1.0})
    mapper = Mapper(idx, MapperConfig(engine="compacted", chunk_reads=32),
                    injector=inj)
    with pytest.raises(InjectedFault, match="fetch_error"):
        mapper.map(reads)


def test_stalled_run_contained_by_resilient_mapper(world):
    _, idx, reads = world

    class StallOnce(FaultInjector):
        def __init__(self):
            super().__init__(stall_s=2.0, rates={"fetch_stall": 1.0})
            self._shots = 1

        def fire(self, site):
            if site == "fetch_stall" and self._shots > 0:
                self._shots -= 1
                return True
            return False

    inj = StallOnce()
    mapper = Mapper(idx, MapperConfig(engine="compacted", chunk_reads=32),
                    injector=inj, watchdog_s=0.25)
    res, mask, counters = ResilientMapper(mapper, FAST).map(reads)
    # the wedged run is retried and the retry goes through clean
    assert not mask.any() and counters["retries"] == 1
    base = Mapper(idx, MapperConfig(engine="compacted")).map(reads)
    np.testing.assert_array_equal(res.position, base.position)


# ----------------------------------------------------- degrade ladder

def test_fail_engines_forces_degrade_to_compacted(world):
    _, idx, reads = world
    inj = FaultInjector(fail_engines=["fused"])
    mapper = Mapper(idx, MapperConfig(engine="fused", wf_backend="jnp"),
                    injector=inj)
    rm = ResilientMapper(mapper, RetryPolicy(max_attempts=2, backoff_s=0.0,
                                             bisect_min=4, degrade_after=1),
                         injector=inj)
    res, mask, counters = rm.map(reads)
    assert rm.ladder.degraded and rm.cfg.engine == "compacted"
    assert counters["degraded_steps"] == 1
    # after the step down, every read still maps — on the fallback rung
    assert not mask.any()
    base = Mapper(idx, MapperConfig(engine="compacted",
                                    wf_backend="jnp")).map(reads)
    np.testing.assert_array_equal(res.position, base.position)
    np.testing.assert_array_equal(res.distance, base.distance)
    # sticky: the next batch goes straight to the fallback, no failures
    res2, mask2, c2 = rm.map(reads[:32])
    assert not mask2.any() and c2["retries"] == 0


# ------------------------------------------------------- service soak

def _soak(svc, reads, idx, n_flushes=4, seed=0):
    """Submit random request sizes across flushes; assert the resolve
    contract and that healthy results match a fault-free session."""
    clean = Mapper(idx, MapperConfig(engine="compacted"))
    rng = np.random.default_rng(seed)
    for _ in range(n_flushes):
        reqs, rids = [], []
        for _ in range(int(rng.integers(1, 4))):
            n = int(rng.integers(3, 33))
            lo = int(rng.integers(0, len(reads) - n))
            reqs.append(reads[lo : lo + n])
            rids.append(svc.submit(reqs[-1]))
        out = svc.flush()
        assert sorted(out) == sorted(rids)      # exactly-once resolve
        for rid, req in zip(rids, reqs):
            got = out[rid]
            if isinstance(got, MappingError):
                assert got.error_type in ("execution", "internal")
                continue
            base = clean.map(req)
            failed = got.failed if got.failed is not None \
                else np.zeros(len(req), bool)
            np.testing.assert_array_equal(got.position[~failed],
                                          base.position[~failed])
            assert not got.mapped[failed].any()
        assert svc.flush() == {}                # nothing stranded


def test_service_soak_single_topology(world):
    _, idx, reads = world
    inj = FaultInjector(seed=5, rates={"bucket": 0.3})
    svc = MappingService(idx, MapperConfig(engine="compacted"),
                         BatcherConfig(bucket_min=8, bucket_max=32),
                         retry=FAST, injector=inj)
    _soak(svc, reads, idx)
    assert inj.fired.get("bucket", 0) >= 1      # the chaos was real
    assert svc.totals["retries"] >= 1


def test_service_soak_mesh_topology(world, mesh1):
    _, idx, reads = world
    mesh, sidx = mesh1
    inj = FaultInjector(seed=6, rates={"bucket": 0.3})
    mapper = Mapper(sidx, topology="mesh", mesh=mesh, injector=inj)
    svc = MappingService(mapper, batcher=BatcherConfig(bucket_min=8,
                                                       bucket_max=32),
                         retry=FAST, injector=inj)
    _soak(svc, reads, idx)
    assert inj.fired.get("bucket", 0) >= 1


def test_paired_request_quarantine_splits_per_mate(world):
    _, idx, reads = world
    # poison a row in the R1 half of the stacked paired block
    inj = FaultInjector(poison_rows=[2])
    svc = MappingService(idx, MapperConfig(engine="compacted"),
                         BatcherConfig(bucket_min=8, bucket_max=32),
                         retry=FAST, injector=inj)
    rid = svc.submit_paired(reads[:8], reads[8:16])
    res1, res2 = svc.flush()[rid]
    assert res1.failed is not None and res1.failed.any()
    assert not res1.mapped[res1.failed].any()
    assert res2.failed is None or not res2.failed.any()
    base2 = Mapper(idx, MapperConfig(engine="compacted")).map(reads[8:16])
    np.testing.assert_array_equal(res2.position, base2.position)


# ------------------------------------------------------------ CLI e2e

def test_map_fastq_chaos_run_completes_and_validates(world, tmp_path):
    from repro.data.genome import write_fasta, write_fastq
    from repro.data.genome import make_reference, sample_reads
    from repro.io.sam import validate_sam
    ref = make_reference(8_000, seed=21)
    rs = sample_reads(ref, 160, seed=22, both_strands=True)
    names = [f"r{i}" for i in range(160)]
    fa, fq = str(tmp_path / "ref.fa"), str(tmp_path / "reads.fq")
    out, rej = str(tmp_path / "out.sam"), str(tmp_path / "rej.fq")
    write_fasta(fa, [("chr1", ref)])
    write_fastq(fq, rs, names=names)
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.map_fastq", fa, fq,
         "-o", out, "--chunk-reads", "64",
         "--on-error", "permissive", "--rejects", rej,
         "--inject", "record=0.02,bucket=0.125,seed=3,poison=7"],
        env={**os.environ, "PYTHONPATH": SRC},
        capture_output=True, text=True, timeout=480)
    assert p.returncode == 0, p.stderr[-3000:]
    assert os.path.exists(out) and not os.path.exists(out + ".partial")
    text = open(out).read()
    validate_sam(text)
    sam_names = [ln.split("\t")[0] for ln in text.splitlines()
                 if ln and not ln.startswith("@")]
    rejected = [ln[1:].split()[0] for ln in open(rej).read().splitlines()
                if ln.startswith("@")]
    # exactly the injected-corrupt records are quarantined to the rejects
    # file; every other read made it into the SAM exactly once (poisoned
    # rows stay in the SAM as synthesized unmapped records)
    assert rejected and len(rejected) < 20
    assert sorted(sam_names + rejected) == sorted(names)
    assert "quarantined:" in p.stderr and "resilience:" in p.stderr
    unmapped = sum(int(ln.split("\t")[1]) & 4 != 0
                   for ln in text.splitlines()
                   if ln and not ln.startswith("@"))
    assert unmapped >= 16       # the poisoned blocks landed as FLAG 4
