"""``repro.obs``: metrics registry + span tracing units, the
timed()-level agreement between spans / counters / ``stage_times_s``,
the accumulation properties behind the launchers' closing stats, and
the registry-derived closing-stats byte-match on both topologies."""
import io
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import streaming
from repro.core.mapper import (_METRIC_RUN_FIELDS, Mapper, MapperStats,
                               accumulate_partition_stats, accumulate_stats,
                               totals_from_registry)
from repro.core.pipeline import MapperConfig
from repro.obs import registry as obs_registry
from repro.obs import tracing as obs_tracing
from repro.obs.registry import MAX_LABEL_SETS, MetricsRegistry
from repro.obs.tracing import Tracer
from repro.obs.validate import validate_chrome_trace, validate_json


@pytest.fixture(autouse=True)
def _clean_obs():
    """Obs state is process-global; never leak an armed registry/tracer
    (or a stale thread-local span context) into another test."""
    yield
    obs_tracing.disable_tracing()
    obs_registry.disable_metrics()
    obs_tracing.clear_ctx()


@pytest.fixture(scope="module")
def world():
    from repro.core.index import build_index
    from repro.data.genome import make_reference, sample_reads
    ref = make_reference(8_000, seed=11, repeat_frac=0.03)
    idx = build_index(ref)
    rs = sample_reads(ref, 48, seed=13)
    return idx, rs.reads


# ---------------------------------------------------------------- registry

def test_counter_gauge_basics():
    reg = MetricsRegistry()
    reg.counter("c_total").inc()
    reg.counter("c_total").inc(4)
    assert reg.counter("c_total").value == 5
    reg.gauge("g", shard="0").set(7)
    reg.gauge("g", shard="0").dec(2)
    assert reg.gauge("g", shard="0").value == 5
    snap = reg.snapshot()
    assert snap["counters"]["c_total"] == 5
    assert snap["gauges"]['g{shard="0"}'] == 5


def test_registry_rejects_kind_mixing():
    reg = MetricsRegistry()
    reg.counter("m")
    with pytest.raises(ValueError):
        reg.gauge("m")


def test_label_cardinality_is_bounded():
    reg = MetricsRegistry()
    for i in range(MAX_LABEL_SETS * 3):
        reg.counter("hot_total", tenant=f"t{i}").inc()
    snap = reg.snapshot()["counters"]
    series = [k for k in snap if k.startswith("hot_total")]
    assert len(series) <= MAX_LABEL_SETS + 1
    assert 'hot_total{other="true"}' in snap  # overflow series absorbs


def test_histogram_quantiles_and_bounded_buckets():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds")
    for v in (0.001, 0.002, 0.004, 0.1, 2.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 5
    assert snap["sum"] == pytest.approx(2.107)
    assert sum(snap["buckets"].values()) == 5
    p50, p95, p99 = h.quantile(0.5), h.quantile(0.95), h.quantile(0.99)
    assert 0.002 <= p50 <= 0.01          # bucket upper edges
    assert p50 <= p95 <= p99
    h.observe(1e9)                       # beyond the last edge
    assert "+Inf" in h.snapshot()["buckets"]
    # memory is fixed: the bucket layout never grows with observations
    assert len(h.counts) == len(h.edges) + 1


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("req_total", code="200").inc(3)
    reg.histogram("lat_seconds").observe(0.5)
    text = reg.to_prometheus()
    assert '# TYPE req_total counter' in text
    assert 'req_total{code="200"} 3' in text
    assert '# TYPE lat_seconds histogram' in text
    assert 'lat_seconds_count 1' in text
    assert 'lat_seconds_sum 0.5' in text
    assert 'le="+Inf"' in text


# ----------------------------------------------------------------- tracing

def test_tracer_spans_ctx_and_chrome_export():
    tr = Tracer()
    obs_tracing.set_ctx(chunk=3)
    with tr.span("work", shard=1):
        pass
    obs_tracing.clear_ctx()
    assert len(tr) == 1
    trace = tr.chrome()
    assert validate_chrome_trace(trace) == []
    xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert xs[0]["name"] == "work"
    assert xs[0]["args"] == {"chunk": 3, "shard": 1}
    assert all(k in xs[0] for k in ("pid", "tid", "ts", "dur"))


def test_tracer_bounds_memory():
    tr = Tracer(max_events=4)
    for _ in range(10):
        tr.add("e", 0.0, 1.0)
    assert len(tr) == 4 and tr.dropped == 6
    assert tr.chrome()["dropped_events"] == 6


def test_trace_validator_catches_malformed():
    assert validate_chrome_trace({"traceEvents": []}) != []  # empty
    bad = {"traceEvents": [{"ph": "X", "name": "a", "pid": 1, "tid": 0,
                            "ts": 0.0}]}          # missing dur
    assert any("dur" in e for e in validate_chrome_trace(bad))
    unbalanced = {"traceEvents": [
        {"ph": "B", "name": "a", "pid": 1, "tid": 0, "ts": 0.0}]}
    assert validate_chrome_trace(unbalanced) != []


def test_mini_schema_validator():
    schema = {"type": "object", "required": ["n"],
              "properties": {"n": {"type": "integer", "minimum": 0}}}
    assert validate_json({"n": 3}, schema) == []
    assert validate_json({"n": -1}, schema) != []
    assert validate_json({}, schema) != []


# ------------------------------------------------- timed()-level agreement

def test_timed_feeds_times_span_and_counter_from_same_clock_reads():
    reg = obs_registry.enable_metrics(MetricsRegistry())
    tr = obs_tracing.enable_tracing(tracer_=Tracer())
    times = {}
    t0 = time.perf_counter()
    t1 = streaming.timed(times, "stage_x", t0)
    assert t1 >= t0
    assert tr.stage_totals()["stage_x"] == times["stage_x"]
    assert reg.counter("repro_stage_seconds_total",
                       stage="stage_x").value == times["stage_x"]
    # times=None (profiling off) emits nothing: the disabled path stays
    # a pure clock read
    n = len(tr)
    streaming.timed(None, "stage_y", t0)
    assert len(tr) == n
    assert "stage_y" not in tr.stage_totals()


def test_trace_durations_equal_stage_times(world):
    """The acceptance property: a traced run's summed span durations are
    the ``stage_times_s`` dict — same clock reads, so equality is exact,
    not approximate."""
    idx, reads = world
    tr = obs_tracing.enable_tracing(tracer_=Tracer())
    cfg = MapperConfig.from_index(idx, chunk_reads=16, profile=True)
    res = Mapper(idx, cfg).map(reads[:32])
    st = res.stats["stage_times_s"]
    totals = tr.stage_totals()
    assert set(st) <= set(totals)
    for k, v in st.items():
        assert totals[k] == pytest.approx(v, rel=1e-9), k
    # full precision survives in the stats dict (no 4-decimal rounding
    # at collection)
    assert any(v != round(v, 4) for v in st.values() if v)


def test_mesh_profile_records_stage_times(world):
    from repro.core.distributed import shard_index
    from repro.core.mapper import _flat_mesh
    idx, reads = world
    cfg = MapperConfig.from_index(idx, profile=True)
    res = Mapper(shard_index(idx, 1), cfg, topology="mesh",
                 mesh=_flat_mesh(1)).map(reads[:16])
    assert set(res.stats["stage_times_s"]) == {"dispatch", "d2h"}


# ------------------------------------------- accumulation properties

def _mk_stats(vals):
    return MapperStats(topology="single", engine="compacted",
                       reads=vals[0], candidates=vals[1],
                       survivors=vals[2], affine_instances=vals[3],
                       padded_affine_instances=vals[4],
                       dropped_send=vals[5], dropped_affine=vals[6],
                       reverse_best=vals[7])


@settings(max_examples=25, deadline=None)
@given(st.lists(st.lists(st.integers(min_value=0, max_value=10**6),
                         min_size=8, max_size=8),
                min_size=1, max_size=6))
def test_accumulate_stats_split_equals_one_shot(chunks):
    """Accumulating per-chunk stats equals accumulating the one-shot sum
    — the property that makes chunked launcher totals trustworthy."""
    split = {f: 0 for f in _METRIC_RUN_FIELDS}
    for vals in chunks:
        accumulate_stats(split, _mk_stats(vals), fields=_METRIC_RUN_FIELDS)
    merged = _mk_stats([sum(v[i] for v in chunks) for i in range(8)])
    one_shot = {f: 0 for f in _METRIC_RUN_FIELDS}
    accumulate_stats(one_shot, merged, fields=_METRIC_RUN_FIELDS)
    assert split == one_shot


@settings(max_examples=25, deadline=None)
@given(st.lists(st.lists(st.integers(min_value=0, max_value=10**6),
                         min_size=5, max_size=5),
                min_size=1, max_size=6))
def test_accumulate_partition_stats_split_equals_one_shot(runs):
    """Per-partition counters and count vectors sum across runs; static
    descriptors take the latest run's value."""
    def mk(v):
        s = _mk_stats([0] * 8)
        s.extra["partitions"] = {
            "partition_loads": v[0], "h2d_bytes": v[1],
            "minis_routed_per_partition": [v[2], v[3]],
            "arena_rows": v[4],          # static: latest wins
        }
        return s
    split = {}
    for v in runs:
        accumulate_partition_stats(split, mk(v))
    part = split["partitions"]
    assert part["partition_loads"] == sum(v[0] for v in runs)
    assert part["h2d_bytes"] == sum(v[1] for v in runs)
    assert part["minis_routed_per_partition"] == [
        sum(v[2] for v in runs), sum(v[3] for v in runs)]
    assert part["arena_rows"] == runs[-1][4]


# -------------------------------- registry-derived closing stats

def _closing_lines(mapper, totals) -> str:
    from repro.launch.serve import _print_mapper_stats
    buf = io.StringIO()
    _print_mapper_stats(mapper, totals, file=buf)
    return buf.getvalue()


def _run_chunked(mapper, reads, step):
    totals = {f: 0 for f in _METRIC_RUN_FIELDS}
    for lo in range(0, len(reads), step):
        res = mapper.map(reads[lo:lo + step])
        accumulate_stats(totals, res.stats, fields=_METRIC_RUN_FIELDS)
    return totals


@pytest.mark.parametrize("topology", ["single", "mesh"])
def test_registry_closing_stats_byte_match(world, topology):
    """Totals re-derived from the metrics registry render the exact same
    closing-stats bytes as the legacy accumulate_stats path, on both
    topologies — the numbers can never disagree."""
    idx, reads = world
    if topology == "mesh":
        from repro.core.distributed import shard_index
        from repro.core.mapper import _flat_mesh
        mapper = Mapper(shard_index(idx, 1), MapperConfig.from_index(idx),
                        topology="mesh", mesh=_flat_mesh(1))
    else:
        mapper = Mapper(idx, MapperConfig.from_index(idx, chunk_reads=16))
    reg = obs_registry.enable_metrics(MetricsRegistry())
    totals = _run_chunked(mapper, reads, 24)
    derived = totals_from_registry(topology, reg)
    assert derived == totals
    assert (_closing_lines(mapper, dict(totals))
            == _closing_lines(mapper, dict(derived)))


def test_totals_from_registry_none_when_disabled():
    assert totals_from_registry("single") is None


# ------------------------------------------------- service-level metrics

def test_service_latency_metrics_and_tenant_bound(world):
    from repro.core.serving import _MAX_TENANTS, BatcherConfig
    idx, reads = world
    reg = obs_registry.enable_metrics(MetricsRegistry())
    svc = Mapper(idx, MapperConfig.from_index(idx)).serve(
        BatcherConfig(bucket_min=64, bucket_max=256))
    for i in range(_MAX_TENANTS + 8):
        svc.submit(reads[i % len(reads)][None], tenant=f"tenant{i}")
    assert len(svc._tenant_pending) <= _MAX_TENANTS + 1
    assert svc.tenant_queue_depth["_other"] == 8
    out = svc.flush()
    assert len(out) == _MAX_TENANTS + 8
    assert all(d == 0 for d in svc._tenant_pending.values())
    assert not svc._submit_ts and not svc._tenants  # drained with the rids
    snap = reg.snapshot()
    assert snap["histograms"]["repro_flush_seconds"]["count"] == 1
    assert (snap["histograms"]["repro_request_queue_wait_seconds"]["count"]
            == _MAX_TENANTS + 8)
    assert snap["histograms"]["repro_bucket_execute_seconds"]["count"] >= 1
    tenant_series = [k for k in snap["counters"]
                     if k.startswith("repro_requests_total")]
    assert 0 < len(tenant_series) <= MAX_LABEL_SETS + 1


def test_batcher_bucket_hist_is_bounded(world):
    """The audit satellite: ``bucket_hist`` keys are pow-2 sizes within
    [bucket_min, bucket_max], so long-lived serving cannot grow it."""
    import math

    from repro.core.serving import BatcherConfig, ReadBatcher
    cfg = BatcherConfig(bucket_min=64, bucket_max=1024)
    b = ReadBatcher(150, cfg)
    rng = np.random.default_rng(0)
    for _ in range(40):
        b.submit(np.zeros((int(rng.integers(1, 900)), 150), np.uint8))
        b.drain()
    max_keys = int(math.log2(cfg.bucket_max // cfg.bucket_min)) + 1
    hist = b.stats["bucket_hist"]
    assert len(hist) <= max_keys
    assert all(cfg.bucket_min <= k <= cfg.bucket_max and (k & (k - 1)) == 0
               for k in hist)


def test_metrics_server_round_trip():
    import json
    import urllib.request

    from repro.obs.server import start_metrics_server
    reg = MetricsRegistry()
    reg.counter("up_total").inc()
    srv = start_metrics_server(reg, port=0)
    try:
        base = f"http://{srv.host}:{srv.port}"
        text = urllib.request.urlopen(f"{base}/metrics").read().decode()
        assert "up_total 1" in text
        snap = json.loads(
            urllib.request.urlopen(f"{base}/metrics.json").read())
        assert snap["counters"]["up_total"] == 1
    finally:
        srv.stop()
