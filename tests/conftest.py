import os
import sys

# src/ layout without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Fall back to the vendored deterministic hypothesis stub when the real
# package is unavailable (see tests/_stubs/hypothesis/__init__.py).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.append(os.path.join(os.path.dirname(__file__), "_stubs"))
