import os
import sys

# src/ layout without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
