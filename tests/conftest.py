import os
import sys

import jax

# src/ layout without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Shared version gate for the pre-existing seed failures on this
# container's jax 0.4.37 (jax.sharding.AxisType, the remat
# optimization_barrier differentiation rule, dict-valued cost_analysis —
# all jax >= 0.5 features).  Test files import this and attach their own
# per-failure skipif reasons.
JAX_PRE_05 = jax.__version_info__ < (0, 5, 0)

# Fall back to the vendored deterministic hypothesis stub when the real
# package is unavailable (see tests/_stubs/hypothesis/__init__.py).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.append(os.path.join(os.path.dirname(__file__), "_stubs"))
