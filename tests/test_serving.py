"""Serving-path features: int8 KV cache, chunked attention parity,
sequence-chunked MoE parity, greedy generation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import layers, lm, transformer

KEY = jax.random.key(0)


def test_int8_kv_cache_matches_bf16():
    cfg = reduced(ARCHS["olmo-1b"])
    params = transformer.init_params(cfg, KEY)
    B, T = 2, 6
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    serve = jax.jit(lm.make_serve_step(cfg))
    c_bf = transformer.init_cache(cfg, B, 8)
    c_q = transformer.init_cache(cfg, B, 8, kv_quant=True)
    for t in range(T):
        lg_bf, c_bf = serve(params, c_bf, toks[:, t : t + 1], jnp.int32(t))
        lg_q, c_q = serve(params, c_q, toks[:, t : t + 1], jnp.int32(t))
    a, b = (np.asarray(lg_bf, np.float32), np.asarray(lg_q, np.float32))
    rel = np.abs(a - b).max() / np.abs(a).max()
    assert rel < 0.05, rel
    assert (a.argmax(-1) == b.argmax(-1)).all()
    assert c_q["attn"]["k"].dtype == jnp.int8


def test_chunked_attention_matches_direct():
    rng = np.random.default_rng(0)
    B, S, H, KV, hd = 2, 256, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    direct = layers._sdpa(q, k, v, causal=True)
    chunked = layers._sdpa_chunked(q, k, v, causal=True, q_chunk=64,
                                   kv_chunk=64)
    np.testing.assert_allclose(np.asarray(direct, np.float32),
                               np.asarray(chunked, np.float32),
                               atol=2e-3, rtol=2e-3)
    # bidirectional too (encoder family)
    d2 = layers._sdpa(q, k, v, causal=False)
    c2 = layers._sdpa_chunked(q, k, v, causal=False, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(d2, np.float32),
                               np.asarray(c2, np.float32), atol=2e-3,
                               rtol=2e-3)


def test_moe_seq_chunking_matches_unchunked():
    cfg = reduced(ARCHS["moonshot-v1-16b-a3b"])
    p = layers.init_moe(KEY, cfg)
    rng = np.random.default_rng(1)
    B, S = 2, 2 * layers.MOE_SEQ_CHUNK  # force the chunked path
    # use a tiny MOE_SEQ_CHUNK for test speed
    old = layers.MOE_SEQ_CHUNK
    layers.MOE_SEQ_CHUNK = 32
    try:
        S = 64
        x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)) * 0.1,
                        jnp.bfloat16)
        y_chunked, aux_c = layers.moe(x, p, cfg, layers.NO_SHARD,
                                      capacity_factor=float(cfg.n_experts))
        y_direct, aux_d = layers._moe_chunk(x, p, cfg, layers.NO_SHARD,
                                            capacity_factor=float(
                                                cfg.n_experts))
        # with no capacity drops the outputs must agree exactly
        np.testing.assert_allclose(np.asarray(y_chunked, np.float32),
                                   np.asarray(y_direct, np.float32),
                                   atol=3e-2, rtol=3e-2)
    finally:
        layers.MOE_SEQ_CHUNK = old


def test_greedy_generate_runs():
    cfg = reduced(ARCHS["smollm-135m"])
    params = transformer.init_params(cfg, KEY)
    prompt = jax.random.randint(KEY, (2, 4), 0, cfg.vocab_size)
    out = lm.greedy_generate(params, cfg, prompt, n_new=3)
    assert out.shape == (2, 7)
    assert (np.asarray(out[:, :4]) == np.asarray(prompt)).all()


def test_long_context_decode_reduced():
    """SSM decode cost is O(1) in context length — the long_500k premise."""
    cfg = reduced(ARCHS["falcon-mamba-7b"])
    params = transformer.init_params(cfg, KEY)
    serve = jax.jit(lm.make_serve_step(cfg))
    cache = transformer.init_cache(cfg, 1, 8)  # max_seq irrelevant for SSM
    tok = jnp.ones((1, 1), jnp.int32)
    for t in range(4):
        lg, cache = serve(params, cache, tok, jnp.int32(t))
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    # state sizes independent of "context length"
    n_state = sum(x.size for x in jax.tree.leaves(cache))
    assert n_state < 10 ** 7
